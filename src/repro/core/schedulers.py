"""Decode-instance selection policies (Algorithm 1 + the baseline ladder),
vectorised over the ``ClusterView`` struct-of-arrays state plane.

Every policy is a *scorer plugin* with the same call signature, mirroring the
paper's deployment story (llm-d Endpoint Picker scorer chain / Dynamo KV
router scoring fn).  The ladder, in ablation order (§VI-H):

  RoundRobin        -> no signal
  LoadAware         -> T_queue + T_decode
  CacheAware        -> max prefix hit, load tiebreak
  CacheLoadAware    -> tuned w_cache/w_load composite (Mooncake Conductor /
                       llm-d composite scorer equivalent; "CLA*")
  NetKVTopoOnly     -> CLA* + static tier map (B_tau, L_tau)
  NetKVStatic       -> + self-contention counter n_inflight^tau(p)
  NetKVFull         -> + dynamic congestion c_tau (Algorithm 1 complete)
  NetKVPredictive   -> beyond paper: EWMA one-step congestion forecast
  NetKVBatch        -> beyond paper: batch-level joint assignment (§VII-C
                       'future work'), see batch_assign.py

Scoring is one pass of NumPy array ops over the view's columns — feasibility
mask, s_eff, T_xfer, T_queue, T_decode as Eq. (2)-(7) vectors — instead of a
per-candidate Python loop; ``NetKVFull(backend="pallas")`` routes the fused
Eq. (2)-(7) + argmin through the Pallas ``netkv_score`` kernel (interpret
mode off-TPU).  Decisions, rejection behaviour, and deterministic
tie-breaking are bit-identical to the retired loop kept in ``reference.py``
(see tests/test_view_parity.py).  ``select`` accepts either a maintained
``ClusterView`` or a legacy ``CandidateState`` sequence (coerced).

All policies share the same feasibility filter (line 1 of Alg. 1) and return
``None`` to signal rejection (line 2).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .cost import (
    IterTimeModel,
    deflected_cost,
    effective_bandwidth_tiers,
    transfer_time,
)
from .oracle import OracleView, SelfContentionTracker, EWMACongestionPredictor, TIERS
from .view import ROLE_DECODE, ClusterView, as_cluster_view


@dataclasses.dataclass
class CandidateState:
    """Scheduler-visible state of one decode instance (§III-C).

    Retained as the row-at-a-time compatibility type: ``select`` coerces a
    sequence of these into a one-shot ``ClusterView``.  The simulator itself
    maintains a columnar view and never builds these.
    """

    instance_id: int
    free_memory: float          # m_d, bytes
    queued: int                 # q_d
    batch_size: int             # beta_d
    hit_tokens: float           # lambda_r(d) for the *current* request
    healthy: bool = True
    iter_scale: float = 1.0     # straggler EWMA multiplier (1.0 = nominal)


@dataclasses.dataclass
class RequestInfo:
    """What the scheduler knows about a request at selection time.

    Under streamed chunked prefill (``SimConfig.kv_streaming``) selection
    happens at *first-chunk* readiness, and the two extra fields describe
    the prefill/transfer overlap the network term may credit: bytes keep
    becoming ready for ``prefill_remaining`` more seconds, and only the
    final ``tail_bytes`` are forced to cross the wire after that.  Both
    default to the serial (no-overlap) values, leaving every legacy code
    path bit-identical.
    """

    request_id: int
    input_len: int
    kv_bytes: float             # s_r (Eq. 1), aggregate across TP shards
    prefill_remaining: float = 0.0   # s of prefill still to run (streaming)
    tail_bytes: float | None = None  # final-chunk bytes (None = all of s_eff)


@dataclasses.dataclass
class Decision:
    instance_id: int
    cost: float                 # policy-internal score of the winner
    est_transfer_time: float    # seconds, 0 for network-oblivious policies
    tier: int
    s_eff: float                # effective bytes to move


def _runner_up(idx: np.ndarray, ties: np.ndarray, keys: tuple) -> int:
    """Full-space index of the *second* candidate under the ladder's
    ``(keys..., ties)`` stable lexsort order, or -1 with a lone candidate.

    ``keys`` are idx-space arrays in ``np.lexsort`` order (primary last).
    Computed only on sampled forensics decisions; both dispatch modes pass
    the same key vectors and tie draws, so the runner-up is bit-identical
    whether the winner came from ``select()`` or ``CohortSelector``."""
    if idx.size < 2:
        return -1
    order = np.lexsort((ties,) + keys)
    return int(idx[order[1]])


# --------------------------------------------------------------------------
# Vectorised cost components: Eq. (2)-(7) as array ops over view columns.
# Operation order matches the scalar helpers in cost.py exactly so results
# stay bit-identical to the per-candidate reference loop.
# --------------------------------------------------------------------------

def v_iter_time(iter_model: IterTimeModel, beta: np.ndarray) -> np.ndarray:
    """t_iter(beta) elementwise, including the optional piecewise segments."""
    t = iter_model.a + iter_model.b * np.maximum(beta, 0.0)
    for brk, slope in zip(iter_model.breaks, iter_model.slopes):
        t = np.where(beta > brk, t + slope * (beta - brk), t)
    return t


def v_s_eff(kv_bytes: float, hit_tokens: np.ndarray, input_len: int) -> np.ndarray:
    """Eq. (2): s_eff = s_r * (1 - lambda/l), hit clamped to [0, l]."""
    if input_len <= 0:
        return np.zeros_like(hit_tokens)
    l = float(input_len)
    frac = np.minimum(np.maximum(hit_tokens, 0.0), l) / l
    return kv_bytes * (1.0 - frac)


def v_transfer_time(
    s_eff: np.ndarray,
    tier_row: np.ndarray,
    tier_bandwidth,
    congestion_by_tier,
    n_by_tier,
    tier_latency,
    prefill_remaining: float = 0.0,
    tail_bytes: float | None = None,
) -> np.ndarray:
    """Eq. (3)-(4) gathered through the per-candidate tier row.

    Per-tier effective bandwidths are computed with the scalar cost.py
    helper (4 values), then gathered — identical arithmetic to the loop.

    With ``prefill_remaining``/``tail_bytes`` set (streamed chunked
    prefill), the column credits the prefill/transfer overlap per
    candidate — ``max(s_eff/B_eff, prefill_remaining + tail/B_eff)`` with
    the tail clamped to each candidate's s_eff (a deep prefix hit shrinks
    the tail too); the defaults leave the serial op sequence untouched
    (bit-identical to the reference loop).
    """
    beff = effective_bandwidth_tiers(tier_bandwidth, congestion_by_tier, n_by_tier)
    lat = np.array([tier_latency[t] for t in TIERS], np.float64)
    lat_row = lat[tier_row]
    if prefill_remaining > 0.0 or tail_bytes is not None:
        b_row = beff[tier_row]
        tail = s_eff if tail_bytes is None else \
            np.minimum(np.maximum(tail_bytes, 0.0), s_eff)
        t_stream = np.maximum(s_eff / b_row, prefill_remaining + tail / b_row)
        return np.where(s_eff <= 0.0, lat_row, t_stream + lat_row)
    return np.where(s_eff <= 0.0, lat_row, s_eff / beff[tier_row] + lat_row)


class Scheduler:
    """Base: feasibility mask + shared vectorised component models."""

    name = "base"
    uses_tier = False            # static tier map
    uses_self_contention = False
    uses_congestion = False

    def __init__(self, iter_model: IterTimeModel, beta_max: int, m_min: float = 2 * 1024**3,
                 seed: int = 0):
        self.iter_model = iter_model
        self.beta_max = beta_max
        self.m_min = m_min
        # Unbiased deterministic tie-breaking: scoring ties must not collapse
        # onto low instance ids (that would topology-bias network-oblivious
        # policies, since ids order pods).  One draw per feasible candidate,
        # in candidate order — the same RNG stream the reference loop reads.
        self._rng = np.random.default_rng(seed + 0xC0FFEE)
        # TracePlane decision-forensics hook (``sim/trace.py``); None keeps
        # every select path allocation-free.  Both dispatch modes call
        # ``want_decision()`` once per decision so sampling stays aligned.
        self.trace_hook = None

    def _ties(self, k: int) -> np.ndarray:
        return self._rng.random(k)

    def _note_decision(self, kind, req, prefill_id, cv, oracle, tier_fn,
                       j, j2, *, cost=None, cache=None, load=None, xfer=None):
        """Record one sampled forensics row: winner ``j`` vs runner-up
        ``j2`` (full-space indices, -1 = none), components as full-space
        vectors.  Scalar extraction is synchronous, so reused view scratch
        buffers are safe to pass; congestion is read from the *raw* oracle
        snapshot — never ``_congestion_by_tier``, whose predictive
        override advances an EWMA per call."""
        def pair(vec):
            if vec is None:
                return 0.0, 0.0
            return float(vec[j]), (float(vec[j2]) if j2 >= 0 else float("nan"))

        cost_w, cost_r = pair(cost)
        cache_w, cache_r = pair(cache)
        load_w, load_r = pair(load)
        xfer_w, xfer_r = pair(xfer)
        tier_w = tier_fn(j)
        tier_r = tier_fn(j2) if j2 >= 0 else -1
        self.trace_hook.decision(
            kind, req.request_id, prefill_id,
            int(cv.ids[j]), int(cv.ids[j2]) if j2 >= 0 else -1,
            tier_w, tier_r, float(oracle.congestion.get(tier_w, 0.0)),
            cost_w, cost_r, cache_w, cache_r, load_w, load_r,
            xfer_w, xfer_r)

    def _oracle_tier_fn(self, cv, oracle, prefill_id):
        return lambda jj: oracle.tier_of(prefill_id, int(cv.ids[jj]))

    # -- shared vector components -------------------------------------------
    def _prep(self, req: RequestInfo, cv: ClusterView):
        """(s_eff vector, feasibility mask) — line 1 of Alg. 1.

        Candidates are the ROLE_DECODE rows of the unified instance axis;
        with every row decode (no flips) the role term is all-True and the
        mask is bit-identical to the pre-RolePlane two-pool filter.
        """
        s_eff = v_s_eff(req.kv_bytes, cv.column("hit_tokens"), req.input_len)
        mask = cv.column("healthy") & (cv.column("role") == ROLE_DECODE) \
            & (cv.column("free_memory") >= s_eff + self.m_min)
        return s_eff, mask

    def _t_queue_vec(self, cv: ClusterView) -> np.ndarray:
        """Eq. (6) scaled by the straggler estimate."""
        beta = cv.column("batch")
        blocked = np.maximum(0, cv.column("queued") - (self.beta_max - beta))
        return cv.column("iter_scale") * (blocked * v_iter_time(self.iter_model, beta))

    def _t_decode_vec(self, cv: ClusterView) -> np.ndarray:
        """Eq. (7) scaled by the straggler estimate."""
        return cv.column("iter_scale") * v_iter_time(self.iter_model, cv.column("batch") + 1)

    def _congestion_by_tier(self, oracle: OracleView) -> dict[int, float]:
        if self.uses_congestion:
            return {t: oracle.congestion.get(t, 0.0) for t in TIERS}
        return {t: 0.0 for t in TIERS}

    def _n_by_tier(self, inflight: Optional[SelfContentionTracker],
                   prefill_id: int) -> dict[int, int]:
        if self.uses_self_contention and inflight is not None:
            return {t: inflight.get(prefill_id, t) for t in TIERS}
        return {t: 0 for t in TIERS}

    def _xfer_vec(self, req, cv, prefill_id, oracle, inflight, s_eff, tier_row):
        """T_xfer vector under this policy's information set."""
        return v_transfer_time(
            s_eff, tier_row, oracle.tier_bandwidth,
            self._congestion_by_tier(oracle), self._n_by_tier(inflight, prefill_id),
            oracle.tier_latency,
            prefill_remaining=req.prefill_remaining,
            tail_bytes=req.tail_bytes,
        )

    # -- interface ----------------------------------------------------------
    def select(
        self,
        req: RequestInfo,
        prefill_id: int,
        cands,  # ClusterView | Sequence[CandidateState]
        oracle: OracleView,
        inflight: Optional[SelfContentionTracker] = None,
    ) -> Optional[Decision]:
        raise NotImplementedError

    def select_cohort(
        self,
        items,  # Sequence[dispatch.CohortItem]
        cands,  # ClusterView | Sequence[CandidateState]
        oracle: OracleView,
        inflight: Optional[SelfContentionTracker] = None,
        *,
        hit_matrix,
        hit_fn=None,
        evictions_fn=None,
    ):
        """Batched R-request selection (DispatchPlane, ``core/dispatch.py``).

        Returns a ``CohortSelector`` whose ``select_row(k)`` walk is
        bit-identical — decisions, RNG tie-break stream, side effects — to
        R sequential ``select`` calls against the live view.
        """
        from .dispatch import CohortSelector  # cycle-free late import

        return CohortSelector(
            self, items, as_cluster_view(cands, oracle), oracle, inflight,
            hit_matrix=hit_matrix, hit_fn=hit_fn, evictions_fn=evictions_fn,
        )

    # -- prefill deflection (RolePlane) -------------------------------------
    def select_deflected(self, req: RequestInfo, cands,
                         deflect_eta) -> Optional[Decision]:
        """Score ROLE_DECODE rows as *prefill* targets (deflection).

        The KV is born on the decode host, so Eq. (4) collapses — no wire,
        no tier gather, no self-contention bump; the network term of the
        objective is replaced by the target's deflected-chunk-queue drain
        ETA (``deflect_eta``, relative seconds) and the decode-side
        Eq. (6)/(7) load stays (``core/cost.py::deflected_cost``).
        Feasibility requires room for the request's *full* KV (it
        materialises locally, nothing is prefix-elided): ``m_d >= s_r +
        m_min``.  One RNG tie draw per feasible candidate, same stream as
        ``select`` — with deflection off this is never called and the
        stream is untouched.
        """
        cv = as_cluster_view(cands)
        eta = np.asarray(deflect_eta, np.float64)
        mask = cv.column("healthy") & (cv.column("role") == ROLE_DECODE) \
            & (cv.column("free_memory") >= req.kv_bytes + self.m_min)
        idx = np.flatnonzero(mask)
        if idx.size == 0:
            return None
        cost = deflected_cost(eta, self._t_queue_vec(cv) + self._t_decode_vec(cv))
        ties = self._ties(idx.size)
        j = int(idx[np.lexsort((ties, cost[idx]))[0]])
        return Decision(int(cv.ids[j]), float(cost[j]), 0.0, 0, 0.0)


class RoundRobin(Scheduler):
    name = "rr"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._next = 0

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        cv = as_cluster_view(cands, oracle)
        s_eff, mask = self._prep(req, cv)
        idx = np.flatnonzero(mask)
        if idx.size == 0:
            return None
        ord_ids = np.argsort(cv.ids[idx])
        pos = self._next % idx.size
        j = int(idx[ord_ids[pos]])
        self._next += 1
        iid = int(cv.ids[j])
        tier = oracle.tier_of(prefill_id, iid)
        h = self.trace_hook
        if h is not None and h.want_decision():
            # rr's "runner-up" is the next cursor position.
            j2 = int(idx[ord_ids[(pos + 1) % idx.size]]) if idx.size > 1 else -1
            self._note_decision("rr", req, prefill_id, cv, oracle,
                                self._oracle_tier_fn(cv, oracle, prefill_id),
                                j, j2, cache=cv.column("hit_tokens"))
        return Decision(iid, 0.0, 0.0, tier, float(s_eff[j]))


class LoadAware(Scheduler):
    """min T_queue + T_decode."""

    name = "la"

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        cv = as_cluster_view(cands, oracle)
        s_eff, mask = self._prep(req, cv)
        idx = np.flatnonzero(mask)
        if idx.size == 0:
            return None
        load = self._t_queue_vec(cv) + self._t_decode_vec(cv)
        ties = self._ties(idx.size)
        j = int(idx[np.lexsort((ties, load[idx]))[0]])
        iid = int(cv.ids[j])
        tier = oracle.tier_of(prefill_id, iid)
        h = self.trace_hook
        if h is not None and h.want_decision():
            self._note_decision("la", req, prefill_id, cv, oracle,
                                self._oracle_tier_fn(cv, oracle, prefill_id),
                                j, _runner_up(idx, ties, (load[idx],)),
                                cost=load, cache=cv.column("hit_tokens"),
                                load=load)
        return Decision(iid, float(load[j]), 0.0, tier, float(s_eff[j]))


class CacheAware(Scheduler):
    """max prefix hit length, load as tiebreaker."""

    name = "ca"

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        cv = as_cluster_view(cands, oracle)
        s_eff, mask = self._prep(req, cv)
        idx = np.flatnonzero(mask)
        if idx.size == 0:
            return None
        neg_hit = -cv.column("hit_tokens")
        load = self._t_queue_vec(cv) + self._t_decode_vec(cv)
        ties = self._ties(idx.size)
        j = int(idx[np.lexsort((ties, load[idx], neg_hit[idx]))[0]])
        iid = int(cv.ids[j])
        tier = oracle.tier_of(prefill_id, iid)
        h = self.trace_hook
        if h is not None and h.want_decision():
            self._note_decision("ca", req, prefill_id, cv, oracle,
                                self._oracle_tier_fn(cv, oracle, prefill_id),
                                j, _runner_up(idx, ties,
                                              (load[idx], neg_hit[idx])),
                                cost=neg_hit, cache=cv.column("hit_tokens"),
                                load=load)
        return Decision(iid, float(neg_hit[j]), 0.0, tier, float(s_eff[j]))


class CacheLoadAware(Scheduler):
    """CLA*: w_cache * miss_frac + w_load * normalised load (tuned weights).

    Matches the scoring component of Mooncake's Conductor and llm-d's
    composite scorer; weights per workload from a grid search (§VI-A).
    """

    name = "cla"

    def __init__(self, *args, w_cache: float = 1.0, w_load: float = 1.0, **kwargs):
        super().__init__(*args, **kwargs)
        self.w_cache = w_cache
        self.w_load = w_load

    def _score_vec(self, req: RequestInfo, cv: ClusterView) -> np.ndarray:
        miss = 1.0 - np.minimum(cv.column("hit_tokens"), req.input_len) / max(req.input_len, 1)
        load = (self._t_queue_vec(cv) + self._t_decode_vec(cv)) / self.iter_model(self.beta_max)
        return self.w_cache * miss + self.w_load * load

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        cv = as_cluster_view(cands, oracle)
        s_eff, mask = self._prep(req, cv)
        idx = np.flatnonzero(mask)
        if idx.size == 0:
            return None
        score = self._score_vec(req, cv)
        ties = self._ties(idx.size)
        j = int(idx[np.lexsort((ties, score[idx]))[0]])
        iid = int(cv.ids[j])
        tier = oracle.tier_of(prefill_id, iid)
        h = self.trace_hook
        if h is not None and h.want_decision():
            # Same normalised-load expression the cohort selector caches.
            loadn = (self._t_queue_vec(cv) + self._t_decode_vec(cv)) \
                / self.iter_model(self.beta_max)
            self._note_decision("cla", req, prefill_id, cv, oracle,
                                self._oracle_tier_fn(cv, oracle, prefill_id),
                                j, _runner_up(idx, ties, (score[idx],)),
                                cost=score, cache=cv.column("hit_tokens"),
                                load=loadn)
        return Decision(iid, float(score[j]), 0.0, tier, float(s_eff[j]))


class NetKVFull(Scheduler):
    """Algorithm 1: C[d] = T_xfer + T_queue + T_decode, full oracle.

    ``backend="numpy"`` (default) evaluates Eq. (2)-(7) as one pass of f64
    array ops — bit-identical to the reference loop.  ``backend="pallas"``
    routes the fused scoring + masked argmin through the Pallas
    ``netkv_score`` kernel (f32, lowest-index tie-break; interpret mode
    off-TPU) — parity on the winner is asserted with a cost tolerance.
    """

    name = "netkv-full"
    uses_tier = True
    uses_self_contention = True
    uses_congestion = True

    def __init__(self, *args, backend: str = "numpy",
                 pallas_interpret: bool | None = None, **kwargs):
        super().__init__(*args, **kwargs)
        if backend not in ("numpy", "pallas"):
            raise ValueError(f"unknown scoring backend {backend!r}")
        if backend == "pallas" and self.iter_model.breaks:
            raise ValueError("pallas backend supports linear iter models only")
        self.backend = backend
        self._pallas_interpret = pallas_interpret

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        cv = as_cluster_view(cands, oracle)
        s_eff, mask = self._prep(req, cv)
        idx = np.flatnonzero(mask)
        if idx.size == 0:
            return None
        tier_row = cv.tier_row(prefill_id)
        if self.backend == "pallas" and req.prefill_remaining <= 0.0 \
                and req.tail_bytes is None:
            # The fused kernel evaluates the serial Eq. (3); streamed-chunk
            # decisions (overlap-aware T_xfer) take the NumPy path.
            return self._select_pallas(
                req, prefill_id, cv, oracle, inflight, s_eff, tier_row)
        t_x = self._xfer_vec(req, cv, prefill_id, oracle, inflight, s_eff, tier_row)
        t_q = self._t_queue_vec(cv)
        t_d = self._t_decode_vec(cv)
        cost = t_x + t_q + t_d
        ties = self._ties(idx.size)
        j = int(idx[np.lexsort((ties, cost[idx]))[0]])
        best_tier = int(tier_row[j])
        if inflight is not None:
            inflight.incr(prefill_id, best_tier)  # line 14; decremented on done
        h = self.trace_hook
        if h is not None and h.want_decision():
            self._note_decision(self.name, req, prefill_id, cv, oracle,
                                lambda jj: int(tier_row[jj]),
                                j, _runner_up(idx, ties, (cost[idx],)),
                                cost=cost, cache=cv.column("hit_tokens"),
                                load=t_q + t_d, xfer=t_x)
        return Decision(int(cv.ids[j]), float(cost[j]), float(t_x[j]),
                        best_tier, float(s_eff[j]))

    # -- Pallas scoring path ------------------------------------------------
    def _select_pallas(self, req, prefill_id, cv, oracle, inflight, s_eff, tier_row):
        from repro.kernels.netkv_score import BIG, netkv_score

        if self._pallas_interpret is None:
            import jax

            self._pallas_interpret = jax.default_backend() != "tpu"
        cong = self._congestion_by_tier(oracle)
        nfl = self._n_by_tier(inflight, prefill_id)
        costs, best = netkv_score(
            cv.column("free_memory"), cv.column("queued"), cv.column("batch"),
            cv.column("hit_tokens"), tier_row,
            cv.column("healthy") & (cv.column("role") == ROLE_DECODE),
            cv.column("iter_scale"),
            [oracle.tier_bandwidth[t] for t in TIERS],
            [oracle.tier_latency[t] for t in TIERS],
            [cong[t] for t in TIERS], [nfl[t] for t in TIERS],
            s_r=float(req.kv_bytes), input_len=float(req.input_len),
            iter_a=self.iter_model.a, iter_b=self.iter_model.b,
            m_min=self.m_min, beta_max=self.beta_max,
            interpret=self._pallas_interpret,
        )
        j = int(best)
        best_cost = float(costs[j])
        if not best_cost < BIG / 2:  # all candidates masked infeasible
            return None
        tier = int(tier_row[j])
        se = float(s_eff[j])
        # Decision bookkeeping fields at f64 through the scalar cost model.
        t_x = transfer_time(se, oracle.tier_bandwidth[tier], cong[tier],
                            nfl[tier], oracle.tier_latency[tier])
        if inflight is not None:
            inflight.incr(prefill_id, tier)
        h = self.trace_hook
        if h is not None and h.want_decision():
            self._note_pallas(req, prefill_id, cv, oracle, tier_row, s_eff,
                              cv.column("hit_tokens"), costs, cong, nfl, j,
                              t_x)
        return Decision(int(cv.ids[j]), best_cost, t_x, tier, se)

    def _note_pallas(self, req, prefill_id, cv, oracle, tier_row, s_eff,
                     hit, costs, cong, nfl, j, t_x_w):
        """Forensics row for a kernel-scored decision (numpy-free runner-up:
        the kernel's lowest-index tie-break is a masked argmin over its f32
        cost row).  Shared with the cohort selector's cached-row path so
        both dispatch modes record identical rows."""
        from repro.kernels.netkv_score import BIG

        c = np.asarray(costs)
        j2 = -1
        if c.size > 1:
            masked = c.copy()
            masked[j] = np.inf
            jj = int(np.argmin(masked))
            if float(masked[jj]) < BIG / 2:
                j2 = jj
        xfer_r = float("nan")
        if j2 >= 0:
            tier_r = int(tier_row[j2])
            xfer_r = transfer_time(
                float(s_eff[j2]), oracle.tier_bandwidth[tier_r], cong[tier_r],
                nfl[tier_r], oracle.tier_latency[tier_r])
        # The kernel does not materialise T_queue/T_decode separately;
        # record load as the cost with the (f64-recomputed) T_xfer removed.
        xvec = np.full(c.shape, np.nan)
        xvec[j] = t_x_w
        lvec = np.full(c.shape, np.nan)
        lvec[j] = float(c[j]) - t_x_w
        if j2 >= 0:
            xvec[j2] = xfer_r
            lvec[j2] = float(c[j2]) - xfer_r
        self._note_decision(self.name, req, prefill_id, cv, oracle,
                            lambda jj_: int(tier_row[jj_]), j, j2,
                            cost=c, cache=hit, load=lvec, xfer=xvec)


class NetKVStatic(NetKVFull):
    """Static tier map + self-contention, congestion withheld ('+Self-cont.')."""

    name = "netkv-static"
    uses_congestion = False


class NetKVTopoOnly(NetKVFull):
    """Static tier map only ('+Static' ablation rung)."""

    name = "netkv-topo"
    uses_self_contention = False
    uses_congestion = False

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        # No n_inflight bookkeeping at all on this rung.
        return super().select(req, prefill_id, cands, oracle, inflight=None)


class NetKVPredictive(NetKVFull):
    """Beyond paper: consume an EWMA forecast instead of the raw snapshot."""

    name = "netkv-pred"

    def __init__(self, *args, predictor: EWMACongestionPredictor | None = None, **kwargs):
        super().__init__(*args, **kwargs)
        self.predictor = predictor or EWMACongestionPredictor()

    def _congestion_by_tier(self, oracle: OracleView) -> dict[int, float]:
        self.predictor.update(oracle.congestion)  # one step per decision
        return {t: self.predictor.predict(t) for t in TIERS}


LADDER = {
    "rr": RoundRobin,
    "la": LoadAware,
    "ca": CacheAware,
    "cla": CacheLoadAware,
    "netkv-topo": NetKVTopoOnly,
    "netkv-static": NetKVStatic,
    "netkv-full": NetKVFull,
    "netkv-pred": NetKVPredictive,
}


def make_scheduler(name: str, iter_model: IterTimeModel, beta_max: int, **kw) -> Scheduler:
    try:
        cls = LADDER[name]
    except KeyError:
        from .batch_assign import NetKVBatch  # cycle-free late import

        if name == "netkv-batch":
            return NetKVBatch(iter_model, beta_max, **kw)
        raise ValueError(f"unknown scheduler {name!r}; known: {sorted(LADDER) + ['netkv-batch']}")
    return cls(iter_model, beta_max, **kw)
