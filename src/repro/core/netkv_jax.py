"""Vectorised NetKV scorer in JAX.

Algorithm 1's per-candidate loop (lines 3-13) as a single fused jit
computation over candidate arrays.  At 1000+ node scale the Python loop is
the scheduler's hot path (the paper reports 1.5 ms per decision at 1024
GPUs); this version scores tens of thousands of candidates in microseconds
and is the entry point the Pallas ``netkv_score`` kernel accelerates further.

The arithmetic is bit-identical to ``repro.core.cost`` (the reference oracle
for both this module and the kernel).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .schedulers import CandidateState


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PoolArrays:
    """Struct-of-arrays snapshot of the decode pool."""

    free_memory: jax.Array   # (D,) f32 bytes
    queued: jax.Array        # (D,) i32
    batch: jax.Array         # (D,) i32
    hit_tokens: jax.Array    # (D,) f32
    tier: jax.Array          # (D,) i32 in {0..3}
    healthy: jax.Array       # (D,) bool
    iter_scale: jax.Array    # (D,) f32

    @staticmethod
    def from_candidates(cands, tiers) -> "PoolArrays":
        return PoolArrays(
            free_memory=jnp.asarray([c.free_memory for c in cands], jnp.float32),
            queued=jnp.asarray([c.queued for c in cands], jnp.int32),
            batch=jnp.asarray([c.batch_size for c in cands], jnp.int32),
            hit_tokens=jnp.asarray([c.hit_tokens for c in cands], jnp.float32),
            tier=jnp.asarray(list(tiers), jnp.int32),
            healthy=jnp.asarray([c.healthy for c in cands], bool),
            iter_scale=jnp.asarray([c.iter_scale for c in cands], jnp.float32),
        )

    @staticmethod
    def from_view(cv, prefill_id: int) -> "PoolArrays":
        """Zero-copy-ish snapshot of a ClusterView's columns + tier row."""
        return PoolArrays(
            free_memory=jnp.asarray(cv.column("free_memory"), jnp.float32),
            queued=jnp.asarray(cv.column("queued"), jnp.int32),
            batch=jnp.asarray(cv.column("batch"), jnp.int32),
            hit_tokens=jnp.asarray(cv.column("hit_tokens"), jnp.float32),
            tier=jnp.asarray(cv.tier_row(prefill_id), jnp.int32),
            healthy=jnp.asarray(cv.column("healthy"), bool),
            iter_scale=jnp.asarray(cv.column("iter_scale"), jnp.float32),
        )


@functools.partial(jax.jit, static_argnames=("beta_max",))
def score_pool(
    pool: PoolArrays,
    kv_bytes: jax.Array,      # scalar f32: s_r
    input_len: jax.Array,     # scalar f32: l_r
    tier_bw: jax.Array,       # (4,) f32 bytes/s
    tier_lat: jax.Array,      # (4,) f32 s
    congestion: jax.Array,    # (4,) f32
    n_inflight: jax.Array,    # (4,) i32 for this prefill instance
    iter_a: jax.Array,
    iter_b: jax.Array,
    m_min: jax.Array,
    *,
    beta_max: int,
):
    """Return (costs, best_idx): Eq. (5) per candidate, +inf if infeasible."""
    hit = jnp.minimum(pool.hit_tokens, input_len)
    s_eff = kv_bytes * (1.0 - hit / jnp.maximum(input_len, 1.0))          # Eq. (2)
    beff = (
        tier_bw[pool.tier]
        * (1.0 - congestion[pool.tier])
        / (1.0 + n_inflight[pool.tier].astype(jnp.float32))
    )                                                                      # Eq. (4)
    t_xfer = s_eff / beff + tier_lat[pool.tier]                            # Eq. (3)
    t_iter = (iter_a + iter_b * pool.batch.astype(jnp.float32)) * pool.iter_scale
    blocked = jnp.maximum(0, pool.queued - (beta_max - pool.batch))
    t_queue = blocked.astype(jnp.float32) * t_iter                        # Eq. (6)
    t_dec = (iter_a + iter_b * (pool.batch + 1).astype(jnp.float32)) * pool.iter_scale  # Eq. (7)
    cost = t_xfer + t_queue + t_dec                                        # Eq. (5)
    feasible = pool.healthy & (pool.free_memory >= s_eff + m_min)
    cost = jnp.where(feasible, cost, jnp.inf)
    return cost, jnp.argmin(cost)


# Batched variant: R requests against the same pool snapshot (the window the
# batch-level assigner scores in one shot before its sequential commits).
score_pool_batched = jax.jit(
    jax.vmap(
        score_pool,
        in_axes=(None, 0, 0, None, None, None, 0, None, None, None),
        axis_name="req",
    ),
    static_argnames=("beta_max",),
)


class JaxNetKV:
    """Drop-in NetKV-Full whose argmin runs under jit (same decisions)."""

    name = "netkv-jax"

    def __init__(self, iter_model, beta_max: int, m_min: float = 2 * 1024**3):
        self.iter_model = iter_model
        self.beta_max = beta_max
        self.m_min = m_min

    def select_arrays(self, pool: PoolArrays, req_kv_bytes, req_len, oracle_view,
                      n_inflight_by_tier):
        costs, idx = score_pool(
            pool,
            jnp.float32(req_kv_bytes),
            jnp.float32(req_len),
            jnp.asarray(oracle_view.bandwidth_array(), jnp.float32),
            jnp.asarray(oracle_view.latency_array(), jnp.float32),
            jnp.asarray(oracle_view.congestion_array(), jnp.float32),
            jnp.asarray(n_inflight_by_tier, jnp.int32),
            jnp.float32(self.iter_model.a),
            jnp.float32(self.iter_model.b),
            jnp.float32(self.m_min),
            beta_max=self.beta_max,
        )
        idx = int(idx)
        cost = float(costs[idx])
        if not np.isfinite(cost):
            return None, costs
        return idx, costs
