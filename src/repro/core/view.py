"""ClusterView: struct-of-arrays scheduler-visible state plane (§III-C).

The seed scored candidates by rebuilding a list of ``CandidateState``
dataclasses from scratch on every scheduling event and looping over it in
Python.  At 1000-GPU scale that rebuild+loop *is* the scheduler hot path
(the paper reports 1.5 ms/decision at 1024 GPUs, §VI exp7).  ``ClusterView``
replaces it with one set of parallel NumPy columns that the instance engine
maintains **incrementally**: the columnar ``InstancePlane`` syncs every
scheduler-visible scalar in one vectorised assignment per event (the
retired per-object ``DecodeSim`` writes its slot on each mutation), so a
scheduling event reads the current cluster state with zero allocation and
scores all D candidates as array ops.  ``free_memory`` is clamped at zero
by the writers: decode-side KV growth may overcommit the budget, and a
negative value would score as phantom negative capacity.

Columns (all length ``n``, slot-indexed):

  ids          i64   instance id of each slot
  free_memory  f64   m_d, bytes (evictable cache counts as free)
  queued       i64   q_d
  batch        i64   beta_d
  iter_scale   f64   straggler EWMA multiplier (scheduler-visible estimate)
  healthy      bool  scheduler-visible health (lags true health by the
                     fault detection delay — see Simulation._on_fault)
  hit_tokens   f64   lambda_r(d) scratch column, filled per request.
                     Under streamed chunked prefill (SimConfig.kv_streaming)
                     the fill — and the whole selection pass — happens at
                     *first-chunk* readiness rather than prefill end, and
                     the request's full KV bytes are pinned (free_memory
                     drops) from that earlier instant; the overlap itself
                     reaches the ladder per request via
                     RequestInfo.prefill_remaining / tail_bytes, not as a
                     column (it is candidate-independent).

Tier lookups are row-cached: ``tier_row(src_id)`` returns the (n,) tier
vector from a source instance (prefill or staging store) to every slot,
computed once from the static topology and invalidated only when the pool
membership changes (elastic join).  ``slot_of`` is the O(1) id->index map
that replaces the seed's ``_decode_by_id`` linear scan.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

# Instance roles (RolePlane).  One instance axis, role as a column: the
# schedulers mask candidates to ROLE_DECODE rows, the deflection path masks
# to the same rows when scoring decode hosts as prefill targets, and the
# role-flip controller rewrites the column in place (no pool rebuild).
ROLE_PREFILL = 0
ROLE_DECODE = 1


class ClusterView:
    """Columnar scheduler<->simulator interface over the decode pool."""

    def __init__(self, tier_fn: Optional[Callable[[int, int], int]] = None,
                 capacity: int = 16):
        capacity = max(int(capacity), 1)
        self.tier_fn = tier_fn
        self.n = 0
        self.ids = np.zeros(capacity, np.int64)
        self.free_memory = np.zeros(capacity, np.float64)
        self.queued = np.zeros(capacity, np.int64)
        self.batch = np.zeros(capacity, np.int64)
        self.iter_scale = np.ones(capacity, np.float64)
        self.healthy = np.zeros(capacity, bool)
        self.hit_tokens = np.zeros(capacity, np.float64)
        self.role = np.full(capacity, ROLE_DECODE, np.int64)
        self._slot: dict[int, int] = {}
        self._tier_rows: dict[int, np.ndarray] = {}

    # ------------------------------------------------------------- membership
    def __len__(self) -> int:
        return self.n

    def _grow(self) -> None:
        cap = len(self.ids) * 2
        for name in ("ids", "free_memory", "queued", "batch", "iter_scale",
                     "healthy", "hit_tokens", "role"):
            old = getattr(self, name)
            new = np.full(cap, ROLE_DECODE, old.dtype) if name == "role" \
                else np.zeros(cap, old.dtype)
            new[: self.n] = old[: self.n]
            setattr(self, name, new)

    def add_instance(self, instance_id: int, *, free_memory: float = 0.0,
                     queued: int = 0, batch: int = 0, hit_tokens: float = 0.0,
                     healthy: bool = True, iter_scale: float = 1.0,
                     role: int = ROLE_DECODE) -> int:
        """Register an instance; returns its (stable) column slot."""
        if instance_id in self._slot:
            raise ValueError(f"instance {instance_id} already registered")
        if self.n == len(self.ids):
            self._grow()
        s = self.n
        self.n += 1
        self.ids[s] = instance_id
        self.free_memory[s] = free_memory
        self.queued[s] = queued
        self.batch[s] = batch
        self.iter_scale[s] = iter_scale
        self.healthy[s] = healthy
        self.hit_tokens[s] = hit_tokens
        self.role[s] = role
        self._slot[instance_id] = s
        self._tier_rows.clear()  # cached rows are now one column short
        return s

    def slot_of(self, instance_id: int) -> int:
        """O(1) id -> column index (replaces the _decode_by_id linear scan)."""
        return self._slot[instance_id]

    # ------------------------------------------------------------ tier plane
    def tier_row(self, src_id: int) -> np.ndarray:
        """(n,) tier of the path src_id -> each slot, row-cached."""
        row = self._tier_rows.get(src_id)
        if row is None:
            if self.tier_fn is None:
                raise ValueError("ClusterView has no tier_fn; cannot derive tiers")
            fn = self.tier_fn
            row = np.fromiter(
                (fn(src_id, int(i)) for i in self.ids[: self.n]),
                dtype=np.int64, count=self.n,
            )
            self._tier_rows[src_id] = row
        return row

    # ------------------------------------------------------------- accessors
    def column(self, name: str) -> np.ndarray:
        """Active slice of one column (no copy)."""
        return getattr(self, name)[: self.n]

    # ------------------------------------------------------------ cohort apply
    def apply_assignment(self, slot: int, *, kv_bytes: float = 0.0,
                         queued_delta: int = 0, batch_delta: int = 0) -> None:
        """O(1) column delta for one cohort assignment.

        Between the argmin rows of a batched dispatch only the *winning*
        slot's scheduler-visible scalars move (memory pinned at reserve,
        queue/batch deltas); this applies exactly that delta without a full
        engine resync.  ``free_memory`` clamps at zero like every writer.
        """
        self.free_memory[slot] = max(self.free_memory[slot] - kv_bytes, 0.0)
        if queued_delta:
            self.queued[slot] += queued_delta
        if batch_delta:
            self.batch[slot] += batch_delta

    # ----------------------------------------------------------------- compat
    @classmethod
    def from_candidates(cls, cands: Sequence, tier_fn=None) -> "ClusterView":
        """Coerce a legacy ``CandidateState`` list into a one-shot view."""
        cv = cls(tier_fn=tier_fn, capacity=max(len(cands), 1))
        for c in cands:
            cv.add_instance(
                c.instance_id, free_memory=c.free_memory, queued=c.queued,
                batch=c.batch_size, hit_tokens=c.hit_tokens,
                healthy=c.healthy, iter_scale=c.iter_scale,
            )
        return cv


def as_cluster_view(cands, oracle=None) -> ClusterView:
    """Accept either a maintained ClusterView or a CandidateState sequence."""
    if isinstance(cands, ClusterView):
        return cands
    tier_fn = oracle.tier_of if oracle is not None else None
    return ClusterView.from_candidates(cands, tier_fn=tier_fn)
