"""Reproduce the paper's ablation ladder (Table IV) in one quick run:
CLA* -> +static tier -> +self-contention -> +dynamic congestion.

    PYTHONPATH=src python examples/ablation_study.py
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sim import SimConfig, run_sim
from repro.traces import generate_trace, profile_capacity

cap = profile_capacity("rag")
trace = generate_trace("rag", duration=16.0, target_rps=cap, seed=0)
print(f"RAG @100% ({cap:.1f} rps), {len(trace)} requests, 1 seed (quick)")
print(f"{'policy':14s} {'TTFT':>8s} {'P99':>8s} {'TBT':>7s} {'SLO':>6s} {'xfer':>7s}")
base = None
for sched in ["cla", "netkv-topo", "netkv-static", "netkv-full"]:
    m = run_sim(SimConfig(scheduler=sched, background=0.2, seed=0,
                          warmup=3.0, measure=10.0), trace)
    if base is None:
        base = m.ttft_mean
    print(f"{sched:14s} {m.ttft_mean*1e3:7.0f}ms {m.ttft_p99*1e3:7.0f}ms "
          f"{m.tbt_mean*1e3:6.2f}ms {m.slo_attainment:.3f} {m.xfer_mean*1e3:6.0f}ms "
          f"  ({(1-m.ttft_mean/base)*100:+.1f}% vs CLA*)")
print("expected: the static tier rung captures most of the gain (§VI-H)")
