"""Quickstart: the NetKV decision in 40 lines.

Builds the paper's §III-D worked example with the public API: a 32K-token
request choosing between a same-pod cold-cache instance and a cross-pod
warm-cache instance, and shows dynamic congestion flipping the verdict.

    PYTHONPATH=src python examples/quickstart.py
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (
    CandidateState, H100_TP4_ITER, LLAMA3_70B_KV, RequestInfo,
    make_scheduler,
)
from repro.core.oracle import OracleView, PAPER_TIER_BANDWIDTH, PAPER_TIER_LATENCY

req = RequestInfo(request_id=0, input_len=32_768,
                  kv_bytes=LLAMA3_70B_KV.kv_bytes(32_768))
print(f"KV cache: {req.kv_bytes/1e9:.1f} GB ({LLAMA3_70B_KV.kv_bytes_per_token//1024} KB/token)")

d1 = CandidateState(instance_id=1, free_memory=4e11, queued=0, batch_size=8,
                    hit_tokens=0.5 * req.input_len)          # same-pod, 50% hit
d2 = CandidateState(instance_id=2, free_memory=4e11, queued=0, batch_size=8,
                    hit_tokens=0.9 * req.input_len)          # cross-pod, 90% hit
tier_of = lambda p, d: 2 if d == 1 else 3

netkv = make_scheduler("netkv-full", H100_TP4_ITER, beta_max=64)

for c3, label in [(0.2, "moderate cross-pod congestion"),
                  (0.72, "heavy cross-pod congestion")]:
    view = OracleView(tier_of, PAPER_TIER_BANDWIDTH, PAPER_TIER_LATENCY,
                      congestion={0: 0.0, 1: 0.0, 2: 0.2, 3: c3})
    d = netkv.select(req, 0, [d1, d2], view)
    print(f"{label} (c3={c3}): pick instance {d.instance_id} "
          f"(tier {d.tier}), est transfer {d.est_transfer_time:.2f}s")

# A cache-aware-only scheduler always picks the warm instance:
ca = make_scheduler("ca", H100_TP4_ITER, beta_max=64)
view = OracleView(tier_of, PAPER_TIER_BANDWIDTH, PAPER_TIER_LATENCY,
                  congestion={t: 0.0 for t in range(4)})
print(f"cache-aware-only picks instance "
      f"{ca.select(req, 0, [d1, d2], view).instance_id} regardless — "
      f"Proposition 1's arbitrarily-suboptimal case as context grows.")
