"""End-to-end driver: serve a small real model with batched requests through
the disaggregated cluster — prefill engines, NetKV routing, kv_pack transfer,
continuous-batching decode.  Token output is exact (tested against a
monolithic forward).

    PYTHONPATH=src python examples/serve_netkv.py
"""
import dataclasses, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs import get_spec
from repro.serving import DisaggregatedCluster, ServeRequest

cfg = dataclasses.replace(get_spec("qwen3-14b").smoke, compute_dtype=jnp.float32)
cluster = DisaggregatedCluster(cfg, scheduler="netkv-full", n_prefill=2,
                               n_decode=4, cache_len=64, background=0.2)
rng = np.random.default_rng(0)
shared_prefix = rng.integers(0, cfg.vocab_size, size=16)

reqs = []
for i in range(8):
    # half the requests share a 16-token prefix (prefix-cache hits kick in)
    if i % 2 == 0:
        prompt = np.concatenate([shared_prefix, rng.integers(0, cfg.vocab_size, 8)])
    else:
        prompt = rng.integers(0, cfg.vocab_size, size=24)
    reqs.append(ServeRequest(i, prompt, max_new=8, arrival=i * 0.05))

print(f"serving {len(reqs)} requests on a {len(cluster.decode)}-decode cluster")
for r in cluster.serve(reqs):
    print(f"req{r.request_id}: decode@{r.decode_instance} tier{r.tier} "
          f"xfer={r.transfer_bytes/1e3:6.0f}KB t_xfer={r.transfer_time*1e3:5.1f}ms "
          f"ttft={r.ttft*1e3:4.0f}ms tokens={r.tokens}")
print("note: even-numbered requests re-hitting a warm instance ship fewer KV bytes")
