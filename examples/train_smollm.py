"""Train a reduced SmolLM config for a few hundred steps with checkpointing,
then kill + resume to demonstrate bitwise-reproducible restart.

    PYTHONPATH=src python examples/train_smollm.py
"""
import os, sys, shutil
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_spec
from repro.models import init_params
from repro.train import (make_optimizer, make_train_step, restore_latest,
                         save_checkpoint, synth_batch)

spec = get_spec("smollm-135m")
cfg = spec.smoke
ckpt = "artifacts/ckpt/example-smollm"
shutil.rmtree(ckpt, ignore_errors=True)

opt = make_optimizer("adamw", lr=3e-3)
params = init_params(cfg, jax.random.PRNGKey(0))
state = opt.init(params)
step_fn = jax.jit(make_train_step(cfg, opt, microbatches=2, batch_shards=1))

STEPS = 300
losses = []
for i in range(STEPS):
    batch = synth_batch(cfg, global_batch=8, seq_len=64, seed=0, step=i)
    params, state, m = step_fn(params, state, batch)
    losses.append(float(m["loss"]))
    if i == 149:
        save_checkpoint(ckpt, 150, {"p": params, "o": state})
    if i % 50 == 0:
        print(f"step {i:4d}  loss {losses[-1]:.4f}")
print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}) — must decrease")
assert losses[-1] < losses[0] - 1.0

# preemption drill: resume from step 150 and rejoin the same trajectory
step0, tree = restore_latest(ckpt, {"p": params, "o": state})
p2, s2 = tree["p"], tree["o"]
for i in range(step0, STEPS):
    batch = synth_batch(cfg, global_batch=8, seq_len=64, seed=0, step=i)
    p2, s2, m = step_fn(p2, s2, batch)
same = all(np.array_equal(np.asarray(a), np.asarray(b))
           for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
print(f"restart from step {step0}: bitwise identical = {same}")
assert same
